"""Megakernel region engine (DESIGN.md §10): one device dispatch per
launch, device-polled preemption via the host-writable flag buffer.

Covers: single-dispatch bit-identity against both the sync and pipelined
engines; flag-forced preemption at EVERY chunk boundary with same-region
(device-resident) resume, cross-region (host materialize) resume, and
cross-shell checkpoint migration; a hypothesis property over
(budget, preempt_at) pairs; the stale-budget re-upload regression; the
bounded-exponential-backoff wait; and the scheduler/shell report counters.
"""
import time

import numpy as np
import pytest

try:  # property tests degrade to deterministic variants without the dep
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on minimal containers
    HAVE_HYPOTHESIS = False

from repro.controller.kernels import get_kernel
from repro.core.interrupts import EventKind
from repro.core.region import _POLL_MAX_S, _POLL_MIN_S
from repro.core.scheduler import Scheduler, SchedulerConfig
from repro.core.shell import Shell
from repro.core.task import Task, TaskStatus
from repro.kernels.blur.tasks import make_image

SIZE = 30


def _blur_task(rng, iters=2, kernel="MedianBlur", img=None):
    if img is None:
        img = make_image(rng, SIZE)
    kd = get_kernel(kernel)
    t = Task(kernel=kernel,
             args=kd.bundle(img, np.zeros_like(img), H=SIZE, W=SIZE,
                            iters=iters))
    return t, img


def _drive(shell, task, arm=None, rearm=False, resume_region=None,
           timeout=60.0):
    """Drive one task on region 0.  ``arm`` writes the one-shot
    ``preempt_at_boundary`` flag before (each, if ``rearm``) launch — the
    deterministic megakernel preemption hook; sync/pipelined engines
    ignore it, so the same driver produces uninterrupted reference runs.
    Returns the preemption count."""
    regions = shell.regions
    target = regions[0]
    target.enqueue_reconfig(task)
    if arm is not None:
        task.preempt_at_boundary = arm
    target.enqueue_launch(task)
    preemptions = 0
    deadline = time.perf_counter() + timeout
    while True:
        assert time.perf_counter() < deadline, f"stuck: {task}"
        ev = shell.interrupts.wait(0.0005)
        if ev is None:
            continue
        if ev.kind is EventKind.TASK_DONE:
            break
        if ev.kind is EventKind.TASK_PREEMPTED:
            preemptions += 1
            target.cancel_preempt()
            target = resume_region if resume_region is not None else target
            target.enqueue_reconfig(task)
            if rearm and arm is not None:
                task.preempt_at_boundary = arm
            target.enqueue_launch(task)
    for r in regions:
        r.cancel_preempt()
    return preemptions


def _reference(img, iters, budget=2):
    """Uninterrupted synchronous run: the bit-identity reference, plus its
    chunk count (the megakernel must execute exactly as many)."""
    shell = Shell(n_regions=1, chunk_budget=budget, engine="sync",
                  prefetch=False)
    try:
        t, _ = _blur_task(np.random.default_rng(0), iters=iters, img=img)
        _drive(shell, t)
        return (tuple(np.asarray(b) for b in t.result),
                shell.regions[0].stats.chunks)
    finally:
        shell.shutdown()


# ---------------------------------------------------------- single dispatch
def test_megakernel_single_dispatch_bit_identity():
    """An unpreempted launch is ONE dispatch regardless of budget, runs
    exactly the sync engine's chunk count on-device, and its output is
    bit-identical to both reference engines."""
    rng = np.random.default_rng(7)
    img = make_image(rng, SIZE)
    ref, n_chunks = _reference(img, iters=2)

    pipe = Shell(n_regions=1, chunk_budget=2, engine="pipelined",
                 prefetch=False)
    try:
        tp, _ = _blur_task(rng, iters=2, img=img)
        _drive(pipe, tp)
        assert all(np.array_equal(a, b) for a, b in zip(tp.result, ref))
    finally:
        pipe.shutdown()

    shell = Shell(n_regions=1, chunk_budget=2, engine="megakernel",
                  prefetch=False)
    try:
        t, _ = _blur_task(rng, iters=2, img=img)
        _drive(shell, t)
        r = shell.regions[0]
        assert r.stats.megakernel_launches == 1
        assert r.stats.flag_poll_exits == 0
        assert r.stats.chunks == n_chunks
        assert all(np.array_equal(a, b) for a, b in zip(t.result, ref))
        assert all(np.array_equal(a, b) for a, b in zip(t.result, tp.result))
    finally:
        shell.shutdown()


def test_engine_mode_validation():
    with pytest.raises(ValueError, match="unknown engine mode"):
        Shell(n_regions=1, engine="warp-drive", prefetch=False)


# ----------------------------------------------------- flag-timing coverage
def test_flag_at_every_boundary_same_region():
    """Arming the flag at boundary 1 of every launch preempts at EVERY
    chunk boundary; each resume is device-resident (no host spill) and the
    final output is bit-identical to the uninterrupted sync run."""
    rng = np.random.default_rng(8)
    img = make_image(rng, SIZE)
    ref, n_chunks = _reference(img, iters=2)
    assert n_chunks >= 3
    shell = Shell(n_regions=1, chunk_budget=2, engine="megakernel",
                  prefetch=False)
    try:
        t, _ = _blur_task(rng, iters=2, img=img)
        pre = _drive(shell, t, arm=1, rearm=True)
        r = shell.regions[0]
        assert pre == n_chunks - 1
        assert r.stats.flag_poll_exits == pre
        assert r.stats.megakernel_launches == n_chunks  # one chunk each
        assert r.stats.chunks == n_chunks
        assert r.stats.host_spills_avoided == pre  # device-resident resumes
        assert all(np.array_equal(a, b) for a, b in zip(t.result, ref))
    finally:
        shell.shutdown()


def test_flag_exit_cross_region_materialize():
    """Flag-exited context resumed on a DIFFERENT region: the lazy commit
    must materialize through the host, bit-identically."""
    rng = np.random.default_rng(9)
    img = make_image(rng, SIZE)
    ref, n_chunks = _reference(img, iters=3)
    for k in range(1, n_chunks):
        shell = Shell(n_regions=2, chunk_budget=2, engine="megakernel",
                      prefetch=False)
        try:
            t, _ = _blur_task(rng, iters=3, img=img)
            pre = _drive(shell, t, arm=k, resume_region=shell.regions[1])
            assert pre == 1
            assert shell.regions[0].stats.chunks == k  # exact boundary
            assert shell.regions[0].stats.flag_poll_exits == 1
            assert shell.regions[1].stats.host_spills_avoided == 0
            assert all(np.array_equal(a, b)
                       for a, b in zip(t.result, ref)), f"boundary {k}"
        finally:
            shell.shutdown()


def test_flag_exit_cross_shell_migration():
    """A RUNNING megakernel launch checkpoint-migrates across shells: the
    frontend's handoff preempts it via the flag (within one chunk), the
    commit spills through the checksummed checkpoint, and the resumed run
    finishes bit-identically."""
    from repro.cluster import ClusterFrontend

    size, iters = 64, 48  # ~192 chunks at budget 1: a wide RUNNING window
    rng = np.random.default_rng(11)
    img = make_image(rng, size)
    kd = get_kernel("MedianBlur")

    def mk():
        return Task(kernel="MedianBlur",
                    args=kd.bundle(img, np.zeros_like(img), H=size, W=size,
                                   iters=iters))

    ref_shell = Shell(n_regions=1, chunk_budget=1, engine="sync",
                      prefetch=False)
    try:
        t_ref = mk()
        _drive(ref_shell, t_ref)
        ref = tuple(np.asarray(b) for b in t_ref.result)
    finally:
        ref_shell.shutdown()

    fe = ClusterFrontend(n_shells=2, regions_per_shell=1, chunk_budget=1,
                         rebalance=False, engine="megakernel")
    try:
        for node in fe.nodes:  # both shells warm: the migration window is
            node.shell.engine.prewarm(  # the launch, not an XLA compile
                "MedianBlur", t_ref.args, (1,), program="mega")
        t = mk()
        h = fe.submit(t)
        deadline = time.perf_counter() + 30.0
        migrated = False
        while time.perf_counter() < deadline and not migrated:
            if t.status is TaskStatus.RUNNING and fe.migrate(tid=t.tid):
                migrated = True
                break
            time.sleep(0.001)
        assert migrated, "forced migration never completed"
        out = h.result(timeout=60.0)
        assert h.n_migrations == 1
        assert all(np.array_equal(a, b) for a, b in zip(out, ref))
        exits = sum(n.shell.regions[0].stats.flag_poll_exits
                    for n in fe.nodes)
        assert exits >= 1  # the handoff popped the in-flight megakernel
    finally:
        rep = fe.shutdown()
    assert rep["stranded_handles"] == 0 and rep["lost_tasks"] == 0


# ------------------------------------------------- (budget, preempt_at) prop
@pytest.fixture(scope="module")
def prop_shells():
    """One sync + one megakernel shell shared across property examples so
    each distinct signature compiles once per engine.  Budgets vary via
    the per-task ``chunk_budget`` override (itself under test)."""
    sync = Shell(n_regions=1, chunk_budget=2, engine="sync", prefetch=False)
    mega = Shell(n_regions=1, chunk_budget=2, engine="megakernel",
                 prefetch=False)
    yield sync, mega
    sync.shutdown()
    mega.shutdown()


def _check_property(prop_shells, budget, preempt_at, iters, seed):
    sync, mega = prop_shells
    rng = np.random.default_rng(seed)
    img = make_image(rng, SIZE)
    t_ref, _ = _blur_task(rng, iters=iters, img=img)
    t_ref.chunk_budget = budget
    _drive(sync, t_ref)
    t, _ = _blur_task(rng, iters=iters, img=img)
    t.chunk_budget = budget
    _drive(mega, t, arm=preempt_at, rearm=True)
    assert all(np.array_equal(a, b) for a, b in zip(t.result, t_ref.result))


if HAVE_HYPOTHESIS:
    @settings(max_examples=6, deadline=None,
              suppress_health_check=list(HealthCheck))
    @given(budget=st.integers(1, 4), preempt_at=st.integers(1, 8),
           iters=st.integers(1, 3), seed=st.integers(0, 3))
    def test_property_budget_preempt_bit_identity(prop_shells, budget,
                                                  preempt_at, iters, seed):
        """For any (budget, preempt boundary): flag-preempting a megakernel
        at that boundary on every launch never changes the output."""
        _check_property(prop_shells, budget, preempt_at, iters, seed)
else:  # deterministic fallback over the same corners
    @pytest.mark.parametrize("budget,preempt_at,iters,seed", [
        (1, 1, 1, 0), (1, 3, 2, 1), (2, 1, 2, 2), (3, 2, 3, 3),
        (4, 8, 1, 0), (2, 5, 3, 1),
    ])
    def test_property_budget_preempt_bit_identity(prop_shells, budget,
                                                  preempt_at, iters, seed):
        _check_property(prop_shells, budget, preempt_at, iters, seed)


# --------------------------------------------------- stale-budget regression
def _mega_resume_chunks(resume_budget):
    """Preempt a budget-4 megakernel launch at its first boundary,
    override the task budget, resume to completion.  Returns
    (first-launch chunks, resumed chunks, result)."""
    shell = Shell(n_regions=1, chunk_budget=4, engine="megakernel",
                  prefetch=False)
    try:
        t, img = _blur_task(np.random.default_rng(3), iters=2)
        r = shell.regions[0]
        r.enqueue_reconfig(t)
        t.preempt_at_boundary = 1
        r.enqueue_launch(t)
        deadline = time.perf_counter() + 60.0
        while t.status is not TaskStatus.PREEMPTED:
            assert time.perf_counter() < deadline
            time.sleep(0.0005)
        first = r.stats.chunks
        t.chunk_budget = resume_budget
        r.cancel_preempt()
        r.enqueue_launch(t)
        while t.status is not TaskStatus.DONE:
            assert time.perf_counter() < deadline
            time.sleep(0.0005)
        if resume_budget is not None:
            # the override's scalar was actually uploaded (cached by VALUE)
            assert resume_budget in r._budget_scalars
            assert int(r._budget_scalars[resume_budget]) == resume_budget
        return first, r.stats.chunks - first, \
            tuple(np.asarray(b) for b in t.result), img
    finally:
        shell.shutdown()


def test_stale_budget_reuploads_on_resume():
    """Regression: a task requeued with a SMALLER budget after preemption
    must re-upload the budget scalar — the resumed launch runs more,
    smaller chunks, and the result stays bit-identical."""
    first_a, resumed_default, out_default, img = _mega_resume_chunks(None)
    first_b, resumed_small, out_small, _ = _mega_resume_chunks(1)
    assert first_a == first_b == 1  # deterministic boundary placement
    # a stale budget-4 scalar would make these equal
    assert resumed_small > resumed_default
    ref, _ = _reference(img, iters=2)
    assert all(np.array_equal(a, b) for a, b in zip(out_default, ref))
    assert all(np.array_equal(a, b) for a, b in zip(out_small, ref))


def test_task_budget_override_sync_engine():
    """``task.chunk_budget`` is resolved freshly per launch on every
    engine, not just the megakernel."""
    rng = np.random.default_rng(4)
    img = make_image(rng, SIZE)
    counts = {}
    for budget in (None, 1):
        shell = Shell(n_regions=1, chunk_budget=4, engine="sync",
                      prefetch=False)
        try:
            t, _ = _blur_task(rng, iters=2, img=img)
            t.chunk_budget = budget
            _drive(shell, t)
            counts[budget] = shell.regions[0].stats.chunks
        finally:
            shell.shutdown()
    assert counts[1] > counts[None]


# ------------------------------------------------------------ backoff wait
def test_wait_ready_exponential_backoff(monkeypatch):
    """The snapshot wait starts at the floor, doubles per wakeup, and
    saturates at the cap (no fixed-interval core burn on long chunks)."""
    import repro.core.region as region_mod

    shell = Shell(n_regions=1, engine="sync", prefetch=False)
    try:
        delays = []
        monkeypatch.setattr(region_mod.time, "sleep",
                            lambda s: delays.append(s))

        class Snap:
            def __init__(self, n):
                self.n = n

            def is_ready(self):
                self.n -= 1
                return self.n < 0

        shell.regions[0]._wait_ready(Snap(12), abort_on_preempt=False)
        assert delays[0] == pytest.approx(_POLL_MIN_S)
        for a, b in zip(delays, delays[1:]):
            assert b == pytest.approx(min(a * 2.0, _POLL_MAX_S))
        assert max(delays) <= _POLL_MAX_S
        assert delays[-1] == pytest.approx(_POLL_MAX_S)
    finally:
        shell.shutdown()


# --------------------------------------------------------- report counters
def test_scheduler_report_counters_and_schema():
    from repro.core.reporting import SCHEMA

    rng = np.random.default_rng(5)
    shell = Shell(n_regions=1, chunk_budget=2, engine="megakernel",
                  prefetch=False)
    sched = Scheduler(shell, SchedulerConfig())
    tasks = []
    for _ in range(2):
        t, _ = _blur_task(rng, iters=1)
        tasks.append(t)
    rep = sched.run(tasks, quiet=True)
    shell.shutdown()
    assert rep["megakernel_launches"] >= 2
    assert rep["flag_poll_exits"] == 0
    unknown = set(rep) - set(SCHEMA["scheduler"])
    assert not unknown, f"undocumented scheduler report keys: {unknown}"
    shell_rep = shell.reconfig_report()
    for r in shell_rep["regions"].values():
        assert "megakernel_launches" in r and "flag_poll_exits" in r
