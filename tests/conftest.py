import warnings

warnings.filterwarnings("ignore")

# NOTE: no XLA_FLAGS here — smoke tests and benches must see 1 CPU device.
# Only launch/dryrun.py forces 512 virtual devices (and only in its own
# process).

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
