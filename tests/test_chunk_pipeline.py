"""Chunk-pipelined region engine (DESIGN.md §8): bit-identity of the
pipelined hot path against the synchronous reference under forced
preemption at every chunk boundary, lazy device-resident spill (including
a cross-shell migration consuming it), same-bitstream coalescing semantics
on all three policies, the repair queue-drain fix, and the event-driven
Controller wait."""
import os
import threading
import time

import numpy as np
import pytest

try:  # property tests degrade to deterministic variants without the dep
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on minimal containers
    HAVE_HYPOTHESIS = False

from repro.controller.kernels import get_kernel
from repro.core.interrupts import EventKind
from repro.core.policy import (EarliestDeadlineFirst, FcfsPriority,
                               WeightedFairShare)
from repro.core.scheduler import Scheduler, SchedulerConfig
from repro.core.shell import Shell
from repro.core.task import Task, TaskStatus
from repro.kernels.blur.tasks import make_image

SIZE = 30


def _blur_task(rng, iters=2, kernel="MedianBlur", img=None, priority=2,
               deadline_s=None, tenant="default"):
    if img is None:
        img = make_image(rng, SIZE)
    kd = get_kernel(kernel)
    t = Task(kernel=kernel,
             args=kd.bundle(img, np.zeros_like(img), H=SIZE, W=SIZE,
                            iters=iters),
             priority=priority, deadline_s=deadline_s, tenant=tenant)
    return t, img


def _drive(shell, task, preempt_at=None, resume_region=None,
           timeout=60.0):
    """Drive one task on a shell's regions directly (no scheduler):
    launch on region 0, optionally force one preemption once the global
    chunk count reaches ``preempt_at``, resuming on ``resume_region``
    (defaults to region 0).  Returns the task's preemption count."""
    regions = shell.regions
    target = regions[0]
    target.enqueue_reconfig(task)
    target.enqueue_launch(task)
    armed = preempt_at is not None
    preemptions = 0
    total = lambda: sum(r.stats.chunks for r in regions)
    deadline = time.perf_counter() + timeout
    while True:
        assert time.perf_counter() < deadline, f"stuck: {task}"
        ev = shell.interrupts.wait(0.0005)
        if ev is not None and ev.kind is EventKind.TASK_DONE:
            break
        if ev is not None and ev.kind is EventKind.TASK_PREEMPTED:
            preemptions += 1
            target.cancel_preempt()
            target = resume_region if resume_region is not None else target
            target.enqueue_reconfig(task)
            target.enqueue_launch(task)
            continue
        if armed and total() >= preempt_at:
            armed = False
            target.request_preempt()
    for r in regions:  # a preempt that raced completion must not leak
        r.cancel_preempt()
    return preemptions


def _reference(img, iters, kernel="MedianBlur"):
    """Synchronous (pipeline=False), uninterrupted run — the bit-identity
    reference."""
    shell = Shell(n_regions=1, chunk_budget=2, pipeline=False,
                  prefetch=False)
    try:
        t, _ = _blur_task(np.random.default_rng(0), iters=iters,
                          kernel=kernel, img=img)
        _drive(shell, t)
        n_chunks = shell.regions[0].stats.chunks
        return tuple(np.asarray(b) for b in t.result), n_chunks
    finally:
        shell.shutdown()


# ------------------------------------------------------------ bit identity
def test_pipelined_matches_sync_bit_identical():
    rng = np.random.default_rng(7)
    img = make_image(rng, SIZE)
    ref, _ = _reference(img, iters=2)
    shell = Shell(n_regions=1, chunk_budget=2, prefetch=False)
    try:
        t, _ = _blur_task(rng, iters=2, img=img)
        _drive(shell, t)
        assert all(np.array_equal(a, b) for a, b in zip(t.result, ref))
        # the pipeline actually overlapped chunks and discarded exactly the
        # one speculative chunk issued past completion
        assert shell.regions[0].stats.chunks_pipelined > 0
        assert shell.regions[0].stats.chunks_discarded >= 1
    finally:
        shell.shutdown()


def test_preempt_at_every_chunk_boundary_bit_identical():
    """Forcing a preemption at each chunk boundary k (resume on the same
    region, device-resident context) never changes the final output."""
    rng = np.random.default_rng(8)
    img = make_image(rng, SIZE)
    ref, n_chunks = _reference(img, iters=2)
    assert n_chunks >= 3
    for k in range(n_chunks):
        shell = Shell(n_regions=1, chunk_budget=2, prefetch=False)
        shell.regions[0].slowdown_s = 0.02  # make boundaries land reliably
        try:
            t, _ = _blur_task(rng, iters=2, img=img)
            _drive(shell, t, preempt_at=k)
            assert t.status is TaskStatus.DONE
            assert all(np.array_equal(a, b)
                       for a, b in zip(t.result, ref)), f"boundary {k}"
        finally:
            shell.shutdown()


if HAVE_HYPOTHESIS:
    @settings(max_examples=6, deadline=None,
              suppress_health_check=list(HealthCheck))
    @given(budget=st.integers(1, 4), iters=st.integers(1, 3),
           kernel=st.sampled_from(["MedianBlur", "GaussianBlur"]),
           preempt_at=st.integers(0, 8), seed=st.integers(0, 2**16))
    def test_property_pipelined_preemption_equivalence(
            budget, iters, kernel, preempt_at, seed):
        """PROPERTY: pipelined execution with a forced preemption at an
        arbitrary boundary is bit-identical to the synchronous
        uninterrupted run."""
        _check_pipelined_equivalence(budget, iters, kernel, preempt_at,
                                     seed)
else:  # deterministic fallback grid
    @pytest.mark.parametrize("budget,iters,kernel,preempt_at,seed", [
        (1, 2, "MedianBlur", 3, 0),
        (2, 1, "GaussianBlur", 1, 1),
        (3, 3, "MedianBlur", 0, 2),
        (4, 2, "GaussianBlur", 6, 3),
    ])
    def test_property_pipelined_preemption_equivalence(
            budget, iters, kernel, preempt_at, seed):
        _check_pipelined_equivalence(budget, iters, kernel, preempt_at,
                                     seed)


def _check_pipelined_equivalence(budget, iters, kernel, preempt_at, seed):
    rng = np.random.default_rng(seed)
    img = make_image(rng, SIZE)
    sync = Shell(n_regions=1, chunk_budget=budget, pipeline=False,
                 prefetch=False)
    try:
        t_ref, _ = _blur_task(rng, iters=iters, kernel=kernel, img=img)
        _drive(sync, t_ref)
        ref = tuple(np.asarray(b) for b in t_ref.result)
    finally:
        sync.shutdown()
    pipe = Shell(n_regions=1, chunk_budget=budget, prefetch=False)
    pipe.regions[0].slowdown_s = 0.01
    try:
        t, _ = _blur_task(rng, iters=iters, kernel=kernel, img=img)
        _drive(pipe, t, preempt_at=preempt_at)
        assert all(np.array_equal(a, b) for a, b in zip(t.result, ref))
    finally:
        pipe.shutdown()


# ------------------------------------------------------------- lazy spill
def test_same_region_resume_is_device_resident():
    """A preempt+resume cycle on one region must avoid the host round trip
    entirely: the commit stays device-resident and the resume consumes it
    in place."""
    rng = np.random.default_rng(9)
    img = make_image(rng, SIZE)
    ref, _ = _reference(img, iters=3)
    shell = Shell(n_regions=1, chunk_budget=1, prefetch=False)
    region = shell.regions[0]
    region.slowdown_s = 0.02
    try:
        t, _ = _blur_task(rng, iters=3, img=img)
        pre = _drive(shell, t, preempt_at=2)
        assert pre >= 1
        assert region.stats.host_spills_avoided >= 1
        committed = region.bank.restore()
        assert committed is not None and committed.device
        assert committed.owner is region and committed.tid == t.tid
        assert all(np.array_equal(a, b) for a, b in zip(t.result, ref))
        # the committed host copy is produced on demand and cached
        host = committed.materialize()
        assert not host.device and host.tid == t.tid
        assert committed.materialize() is host
    finally:
        shell.shutdown()


def test_cross_region_resume_materializes_host_copy():
    """Resuming on a different region is the actual spill: the lazy commit
    materializes through the host, and the result stays bit-identical."""
    rng = np.random.default_rng(10)
    img = make_image(rng, SIZE)
    ref, _ = _reference(img, iters=3)
    shell = Shell(n_regions=2, chunk_budget=1, prefetch=False)
    for r in shell.regions:
        r.slowdown_s = 0.02
    try:
        t, _ = _blur_task(rng, iters=3, img=img)
        pre = _drive(shell, t, preempt_at=2,
                     resume_region=shell.regions[1])
        assert pre >= 1
        assert shell.regions[1].stats.host_spills_avoided == 0
        assert all(np.array_equal(a, b) for a, b in zip(t.result, ref))
    finally:
        shell.shutdown()


def test_cross_shell_migration_consumes_lazy_spill():
    """Checkpoint-migrating a *running* task to another shell consumes the
    device-resident commit through the checksummed disk spill and resumes
    bit-identically to an uninterrupted single-shell run."""
    from repro.cluster import ClusterFrontend

    rng = np.random.default_rng(11)
    img = make_image(rng, SIZE)
    ref, _ = _reference(img, iters=3)
    fe = ClusterFrontend(n_shells=2, regions_per_shell=1, chunk_budget=1,
                         rebalance=False)
    for node in fe.nodes:
        node.shell.region_slowdown_s = 0.02
        for r in node.shell.regions:
            r.slowdown_s = 0.02
    try:
        t, _ = _blur_task(rng, iters=3, img=img)
        h = fe.submit(t)
        deadline = time.perf_counter() + 20.0
        while (t.status is not TaskStatus.RUNNING
               and time.perf_counter() < deadline):
            time.sleep(0.002)  # only a RUNNING task checkpoint-migrates
        migrated = False
        while time.perf_counter() < deadline and not migrated:
            if t.status is TaskStatus.RUNNING and fe.migrate(tid=t.tid):
                migrated = True
                break
            time.sleep(0.004)
        assert migrated, "forced migration never completed"
        # the lazy commit was spilled through the on-disk checkpoint
        spills = [f for f in os.listdir(fe.spill_dir)
                  if f.startswith(f"task{t.tid}.") and f.endswith(".npz")]
        assert spills, os.listdir(fe.spill_dir)
        out = h.result(timeout=60.0)
        assert h.n_migrations == 1
        assert all(np.array_equal(a, b) for a, b in zip(out, ref))
    finally:
        rep = fe.shutdown()
    assert rep["stranded_handles"] == 0 and rep["lost_tasks"] == 0


# ------------------------------------------------------------- coalescing
def _mk_sched_tasks(rng, kernels, priority=2):
    out = []
    for k in kernels:
        t, _ = _blur_task(rng, iters=1, kernel=k, priority=priority)
        out.append(t)
    return out


def test_coalescing_reduces_reconfigs_and_strands_nothing():
    """[M, G, M] on one region: the finished region picks up the queued
    same-bitstream task back-to-back, so the alternation costs 2 reconfigs
    instead of 3 — and without coalescing it stays 3."""
    reconfigs = {}
    for coalesce in (True, False):
        rng = np.random.default_rng(12)
        shell = Shell(n_regions=1, chunk_budget=2, prefetch=False)
        tasks = _mk_sched_tasks(rng, ["MedianBlur", "GaussianBlur",
                                      "MedianBlur"])
        for k in ("MedianBlur", "GaussianBlur"):
            shell.engine.prewarm(k, tasks[0].args, (1,))
        sched = Scheduler(shell, SchedulerConfig(coalescing=coalesce))
        rep = sched.run(tasks, quiet=True)
        shell.shutdown()
        assert rep["n_done"] == 3
        assert rep["stranded_handles"] == 0
        reconfigs[coalesce] = rep["reconfigs"]
        if coalesce:
            assert rep["coalesced_dispatches"] >= 1
            # the two Median tasks ran back-to-back
            order = sorted(tasks, key=lambda t: t.t_first_served)
            assert [t.kernel for t in order] == [
                "MedianBlur", "MedianBlur", "GaussianBlur"]
        else:
            assert rep["coalesced_dispatches"] == 0
    assert reconfigs[True] < reconfigs[False]


def test_coalescing_never_crosses_priority_levels():
    """A same-bitstream task at a lower priority must NOT jump a
    higher-priority head of a different kernel."""
    rng = np.random.default_rng(13)
    shell = Shell(n_regions=1, chunk_budget=1, prefetch=False)
    shell.regions[0].slowdown_s = 0.02  # m1 still running when g0/m2 queue
    m1, _ = _blur_task(rng, iters=2, kernel="MedianBlur", priority=3)
    g0, _ = _blur_task(rng, iters=1, kernel="GaussianBlur", priority=0)
    m2, _ = _blur_task(rng, iters=1, kernel="MedianBlur", priority=3)
    g0.arrival_time = m2.arrival_time = 0.05
    for k in ("MedianBlur", "GaussianBlur"):
        shell.engine.prewarm(k, m1.args, (1,))
    sched = Scheduler(shell, SchedulerConfig(preemption=False))
    rep = sched.run([m1, g0, m2], quiet=True)
    shell.shutdown()
    assert rep["n_done"] == 3
    # when m1 finished, the same-bitstream m2 was queued behind the urgent
    # Gaussian head — the level-0 head must run first, never be jumped
    assert g0.t_first_served < m2.t_first_served
    assert rep["coalesced_dispatches"] == 0


class _FakeRegion:
    devices = None
    loaded = None


def _match(kernel):
    return lambda t: t.kernel == kernel


def test_fcfs_peek_same_bitstream_semantics():
    rng = np.random.default_rng(14)
    pol = FcfsPriority(5)
    g, _ = _blur_task(rng, kernel="GaussianBlur", priority=0)
    m_low, _ = _blur_task(rng, kernel="MedianBlur", priority=3)
    pol.enqueue(g)
    pol.enqueue(m_low)
    region = _FakeRegion()
    # level 0 owns the region: no cross-level coalescing
    assert pol.peek_same_bitstream(_match("MedianBlur"), region, 8) is None
    # drain level 0 -> the level-3 Median becomes reachable
    assert pol.take(g)
    got = pol.peek_same_bitstream(_match("MedianBlur"), region, 8)
    assert got is m_low
    assert pol.take(got) and not pol.has_pending()


def test_edf_peek_never_skips_a_deadline():
    rng = np.random.default_rng(15)
    pol = EarliestDeadlineFirst()
    d, _ = _blur_task(rng, kernel="GaussianBlur", deadline_s=5.0)
    bg_g, _ = _blur_task(rng, kernel="GaussianBlur")
    bg_m, _ = _blur_task(rng, kernel="MedianBlur")
    for t in (d, bg_g, bg_m):
        pol.enqueue(t)
    region = _FakeRegion()
    # a deadline-bearing head is never jumped for a coalescing win
    assert pol.peek_same_bitstream(_match("MedianBlur"), region, 8) is None
    assert pol.take(d)
    # background tasks may jump other background tasks
    got = pol.peek_same_bitstream(_match("MedianBlur"), region, 8)
    assert got is bg_m and pol.take(got)


def test_wfq_peek_respects_tenant_turn_and_charges_vt():
    rng = np.random.default_rng(16)
    pol = WeightedFairShare()
    a1, _ = _blur_task(rng, kernel="MedianBlur", tenant="a")
    a2, _ = _blur_task(rng, kernel="GaussianBlur", tenant="a")
    a3, _ = _blur_task(rng, kernel="MedianBlur", tenant="a")
    b1, _ = _blur_task(rng, kernel="MedianBlur", tenant="b")
    for t in (a1, a2, a3, b1):
        pol.enqueue(t)
    region = _FakeRegion()
    # tenant a's turn: its head matches directly
    got = pol.peek_same_bitstream(_match("MedianBlur"), region, 8)
    assert got is a1 and pol.take(a1)
    vt_a = pol._vt["a"]
    assert vt_a > 0  # the coalesced dispatch charged a's virtual clock
    # now it is b's turn — a's deeper Median must not be offered
    got = pol.peek_same_bitstream(_match("MedianBlur"), region, 8)
    assert got is b1 and pol.take(b1)
    # back to a: intra-tenant FIFO may bend (a3 jumps the Gaussian a2)
    got = pol.peek_same_bitstream(_match("MedianBlur"), region, 8)
    assert got is a3


# ----------------------------------------------------- repair drain race
def test_repair_returns_dropped_launch_commands():
    """Commands still queued when a dead worker is repaired are handed
    back for requeue instead of being silently dropped."""
    rng = np.random.default_rng(17)
    shell = Shell(n_regions=1, chunk_budget=2, prefetch=False)
    region = shell.regions[0]
    try:
        t1, _ = _blur_task(rng, iters=1)
        t2, _ = _blur_task(rng, iters=1)
        region.inject_failure()
        region.enqueue_launch(t1)  # worker hits the failure and dies
        deadline = time.perf_counter() + 10.0
        while region._thread.is_alive():
            assert time.perf_counter() < deadline
            time.sleep(0.005)
        region.enqueue_launch(t2)  # lands on a dead region's queue
        assert not region.idle
        dropped = region.repair()
        assert dropped == [t2]
        assert region.alive and region.idle
        ev = shell.interrupts.drain()
        assert any(e.kind is EventKind.REGION_FAILED for e in ev)
    finally:
        shell.shutdown()


def test_repair_drain_is_atomic_and_reconciles_inflight():
    """The drain-and-reject happens under the single command lock: every
    command queued on the dead region is either handed back by repair()
    or preserved with a consistent inflight count — never silently lost
    (the seed's check-then-restart window could drop one)."""
    rng = np.random.default_rng(18)
    shell = Shell(n_regions=1, chunk_budget=2, prefetch=False)
    region = shell.regions[0]
    try:
        t0, _ = _blur_task(rng, iters=1)
        region.inject_failure()
        region.enqueue_launch(t0)  # worker dies on it
        deadline = time.perf_counter() + 10.0
        while region._thread.is_alive():
            assert time.perf_counter() < deadline
            time.sleep(0.005)
        shell.interrupts.drain()
        # several dispatches race the dead worker: all must come back
        queued = []
        for _ in range(3):
            t, _ = _blur_task(rng, iters=1)
            region.enqueue_reconfig(t)
            region.enqueue_launch(t)
            queued.append(t)
        assert not region.idle
        dropped = region.repair()
        assert dropped == queued  # launch commands, in posting order
        with region._inflight_lock:
            assert region._inflight == region._q.qsize() == 0
        assert region.alive and region.idle
        # enqueues after the repair behave normally (the lock serialized
        # them against the drain; nothing half-counted)
        t1, _ = _blur_task(rng, iters=1)
        region.enqueue_reconfig(t1)
        region.enqueue_launch(t1)
        _drive_done = time.perf_counter() + 30.0
        while t1.status is not TaskStatus.DONE:
            assert time.perf_counter() < _drive_done
            ev = shell.interrupts.wait(0.01)
            if ev is not None and ev.kind is EventKind.TASK_DONE:
                break
        assert t1.status is TaskStatus.DONE
    finally:
        shell.shutdown()


def test_auto_repair_skips_already_requeued_tasks(monkeypatch):
    """A task the REGION_FAILED handler already requeued (its launch
    command was still sitting in the dead worker's queue) must not be
    enqueued a second time by the auto-repair requeue — that would
    double-dispatch one Task onto two regions concurrently."""
    import time as _time

    rng = np.random.default_rng(20)
    shell = Shell(n_regions=1, chunk_budget=2, prefetch=False)
    try:
        sched = Scheduler(shell, SchedulerConfig(repair_after_s=0.0))
        region = shell.regions[0]
        requeued, _ = _blur_task(rng, iters=1)   # already back in a queue
        dropped_only, _ = _blur_task(rng, iters=1)  # genuinely dropped
        elsewhere, _ = _blur_task(rng, iters=1)  # re-dispatched to another
        for t in (requeued, dropped_only, elsewhere):
            t.status = TaskStatus.QUEUED
            t.last_dispatched_rid = region.rid
        # 'elsewhere' was requeued by the failure handler and then served
        # to a different region whose worker has not started it yet — the
        # drained command is stale and must not resurrect it
        elsewhere.last_dispatched_rid = region.rid + 1
        sched.policy.enqueue(requeued)
        monkeypatch.setattr(region, "repair",
                            lambda: [requeued, dropped_only, elsewhere])
        sched.t0 = _time.perf_counter()
        sched._dead_since[region.rid] = 0.0
        sched._maybe_repair()
        pending = sched.policy.pending_tasks()
        assert sum(1 for t in pending if t is requeued) == 1
        assert sum(1 for t in pending if t is dropped_only) == 1
        assert sum(1 for t in pending if t is elsewhere) == 0
    finally:
        shell.shutdown()


# ------------------------------------------------- event-driven controller
def test_controller_wait_is_event_driven():
    from repro.controller.controller import Controller

    rng = np.random.default_rng(19)
    img = make_image(rng, SIZE)
    shell = Shell(n_regions=1, chunk_budget=2, prefetch=False)
    ctrl = Controller(shell)
    try:
        kd = get_kernel("MedianBlur")  # noqa: F841 - registry warm
        t = ctrl.launch("MedianBlur", (img, np.zeros_like(img)),
                        priority=1, H=SIZE, W=SIZE, iters=1)
        with pytest.raises(TimeoutError):
            ctrl.wait(t, timeout=0.1)  # never run -> no handle registered
        th = threading.Thread(target=ctrl.run, kwargs={"quiet": True})
        th.start()
        # a wait racing run() blocks through handle registration, then on
        # completion — the cross-thread pattern the seed's polling allowed
        got = ctrl.wait(t, timeout=30.0)
        assert got.status is TaskStatus.DONE
        th.join(timeout=30)
        assert not th.is_alive()
    finally:
        ctrl.shutdown()
