"""Substrate tests: optimizer, data pipeline, compression, sharding rules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # degrade, don't error, without the dep
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.optim.adamw import schedule
from repro.optim.compression import (Int8Compressor, _dequantize, _quantize)


def test_adamw_minimizes_quadratic():
    opt = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1,
                      total_steps=200)
    params = {"w": jnp.asarray([3.0, -2.0, 5.0])}
    master, m, v = adamw_init(params, opt)
    step = jnp.int32(0)
    for _ in range(150):
        g = {"w": 2 * master["w"]}  # d/dw (w^2)
        params, master, m, v = adamw_update(g, params, master, m, v, step, opt)
        step = step + 1
    assert float(jnp.abs(master["w"]).max()) < 0.2


def test_adamw_bf16_state_close_to_fp32():
    o32 = AdamWConfig(lr=0.05, weight_decay=0.0, total_steps=100)
    o16 = AdamWConfig(lr=0.05, weight_decay=0.0, total_steps=100,
                      state_dtype="bfloat16")
    p0 = {"w": jnp.linspace(-1, 1, 32)}
    res = {}
    for name, opt in [("f32", o32), ("bf16", o16)]:
        params = jax.tree.map(jnp.copy, p0)
        master, m, v = adamw_init(params, opt)
        step = jnp.int32(0)
        for _ in range(50):
            g = {"w": 2 * master["w"] + 0.1}
            params, master, m, v = adamw_update(g, params, master, m, v,
                                                step, opt)
            step = step + 1
        res[name] = np.asarray(master["w"])
    np.testing.assert_allclose(res["bf16"], res["f32"], atol=0.05)


def test_schedule_warmup_and_decay():
    opt = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_frac=0.1)
    assert float(schedule(jnp.int32(0), opt)) == 0.0
    assert abs(float(schedule(jnp.int32(10), opt)) - 1.0) < 1e-6
    assert float(schedule(jnp.int32(100), opt)) == pytest.approx(0.1, rel=1e-3)
    assert float(schedule(jnp.int32(5), opt)) == pytest.approx(0.5, rel=1e-3)


@settings(max_examples=20, deadline=None,
          suppress_health_check=list(HealthCheck))
@given(st.lists(st.floats(-1e3, 1e3, allow_nan=False, width=32),
                min_size=1, max_size=600))
def test_int8_quantization_error_bound(xs):
    """PROPERTY: blockwise int8 roundtrip error <= max|block| / 127 / 2
    per element (half an LSB of the block scale)."""
    x = jnp.asarray(np.array(xs, np.float32))
    q, scale = _quantize(x)
    deq = _dequantize(q, scale, x.shape)
    err = np.abs(np.asarray(deq) - np.asarray(x))
    # per-block bound
    flat = np.asarray(x)
    pad = (-flat.size) % 256
    blocks = np.pad(flat, (0, pad)).reshape(-1, 256)
    bound = np.abs(blocks).max(1) / 127.0 * 0.5 + 1e-6
    err_blocks = np.pad(err, (0, pad)).reshape(-1, 256)
    assert (err_blocks <= bound[:, None] + 1e-7).all()


def test_error_feedback_is_unbiased_over_time():
    """With error feedback, the SUM of dequantized grads converges to the
    sum of true grads (residual stays bounded)."""
    rng = np.random.default_rng(0)
    g_true = {"w": jnp.asarray(rng.normal(size=300).astype(np.float32))}
    res = Int8Compressor.init_residual(g_true)
    total_deq = jnp.zeros_like(g_true["w"])
    for _ in range(20):
        deq, res = Int8Compressor.apply_with_feedback(g_true, res)
        total_deq = total_deq + deq["w"]
    np.testing.assert_allclose(np.asarray(total_deq / 20),
                               np.asarray(g_true["w"]), atol=2e-2)


def test_data_pipeline_deterministic_and_resumable():
    from repro.data.pipeline import DataConfig, SyntheticTokens

    d1 = SyntheticTokens(DataConfig(seed=7, vocab_size=64, seq_len=32,
                                    global_batch=4))
    d2 = SyntheticTokens(DataConfig(seed=7, vocab_size=64, seq_len=32,
                                    global_batch=4))
    for s in (0, 1, 17, 1000):
        np.testing.assert_array_equal(d1.batch(s)["tokens"],
                                      d2.batch(s)["tokens"])
    # resume: batches(5..) == skipping the first five
    got = [b["tokens"] for _, b in d2.batches(5, 3)]
    want = [d1.batch(5 + i)["tokens"] for i in range(3)]
    for a, b in zip(got, want):
        np.testing.assert_array_equal(a, b)
    # labels are next-token
    b = d1.batch(0)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])
    assert (b["labels"][:, -1] == -1).all()


def test_sharding_rules_divide_all_archs():
    """Every param/cache spec must evenly divide its tensor on the
    production mesh (structural validation, no devices needed)."""
    from jax.sharding import AbstractMesh, PartitionSpec as P

    from repro.configs import SHAPES, all_configs
    from repro.models import transformer as TF
    from repro.sharding import rules as R

    mesh = AbstractMesh((16, 16), ("data", "model"))
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))

    def check(spec: P, shape, where):
        for dim, part in enumerate(spec):
            if part is None:
                continue
            axes = part if isinstance(part, tuple) else (part,)
            total = int(np.prod([sizes[a] for a in axes]))
            assert shape[dim] % total == 0, \
                f"{where}: dim {dim} of {shape} not divisible by {axes}"

    for name, cfg in all_configs().items():
        params = TF.abstract_params(cfg)
        specs = R.param_specs(cfg, mesh, params)
        jax.tree.map(lambda s, l, n=name: check(s, l.shape, n),
                     specs, params,
                     is_leaf=lambda x: isinstance(x, P))
        cache = jax.eval_shape(lambda c=cfg: TF.init_cache(c, 128, 4096))
        cspecs = R.cache_specs(cfg, mesh, cache)
        jax.tree.map(lambda s, l, n=name: check(s, l.shape, n + ".cache"),
                     cspecs, cache, is_leaf=lambda x: isinstance(x, P))
