"""Fault tolerance: region failure -> context-preserving migration; straggler
mitigation; checkpoint/restart equivalence; torn disk commits."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.controller.kernels import get_kernel
from repro.core.scheduler import Scheduler, SchedulerConfig
from repro.core.shell import Shell
from repro.core.task import Task, TaskStatus
from repro.kernels.blur.ref import iterated_blur_ref
from repro.kernels.blur.tasks import make_image

SIZE = 30


def _task(rng, iters=3, priority=2, arrival=0.0):
    img = make_image(rng, SIZE)
    kd = get_kernel("MedianBlur")
    return Task(kernel="MedianBlur",
                args=kd.bundle(img, np.zeros_like(img), H=SIZE, W=SIZE,
                               iters=iters),
                priority=priority, arrival_time=arrival), img


def test_region_failure_migrates_task():
    """Kill the region mid-task: the task must finish on the repaired/other
    region with a correct result (elastic shrink + context recovery)."""
    rng = np.random.default_rng(2)
    t, img = _task(rng, iters=3)
    shell = Shell(n_regions=2, chunk_budget=1)
    shell.regions[0].slowdown_s = 0.01
    sched = Scheduler(shell, SchedulerConfig(preemption=True))

    import threading

    def killer():
        time.sleep(0.15)
        # kill whichever region is running the task
        for r in shell.regions:
            if r.current_task is t:
                r.inject_failure()
                return

    th = threading.Thread(target=killer)
    th.start()
    rep = sched.run([t], quiet=True)
    th.join()
    shell.shutdown()
    assert t.status == TaskStatus.DONE
    ref = np.asarray(iterated_blur_ref(jnp.asarray(img), 3, "median"))
    np.testing.assert_allclose(t.result[1], ref, atol=1e-5)


def test_all_regions_dead_raises():
    rng = np.random.default_rng(3)
    t, _ = _task(rng)
    shell = Shell(n_regions=1, chunk_budget=1)
    shell.regions[0].inject_failure()
    sched = Scheduler(shell, SchedulerConfig(preemption=True))
    with pytest.raises(RuntimeError, match="all regions failed"):
        sched.run([t], quiet=True)
    shell.shutdown()


def test_straggler_migration():
    """A region 50x slower than its peer must lose its task to migration."""
    rng = np.random.default_rng(4)
    tasks = [_task(rng, iters=3, arrival=0.0)[0] for _ in range(6)]
    shell = Shell(n_regions=2, chunk_budget=1)
    # prewarm the executable cache: compile-time noise would otherwise
    # dominate the chunk-latency EWMAs this test is about
    shell.engine.prewarm("MedianBlur", tasks[0].args, (1,))
    shell.regions[1].slowdown_s = 0.05  # straggler
    sched = Scheduler(shell, SchedulerConfig(preemption=True,
                                             straggler_factor=5.0))
    rep = sched.run(tasks, quiet=True)
    shell.shutdown()
    assert rep["n_done"] == 6
    assert rep["migrations"] >= 1, "straggler was never migrated"


def test_train_checkpoint_restart_equivalence(tmp_path):
    """5 straight steps == 3 steps + crash + restart(2 more): identical
    params (data cursor + optimizer state both restored)."""
    from repro.configs import get_config
    from repro.launch.train import train_loop

    cfg = get_config("h2o-danube-3-4b").reduced()
    base_a = str(tmp_path / "a" / "ck")
    base_b = str(tmp_path / "b" / "ck")

    s_full, losses_full = train_loop(cfg, steps=5, batch=2, seq=32,
                                     ckpt_base=base_a, ckpt_every=100,
                                     quiet=True)
    # interrupted run: 3 steps, checkpoint, then "restart" for the last 2
    train_loop(cfg, steps=3, batch=2, seq=32, ckpt_base=base_b,
               ckpt_every=3, quiet=True)
    s_resumed, losses_resumed = train_loop(cfg, steps=5, batch=2, seq=32,
                                           ckpt_base=base_b, ckpt_every=100,
                                           quiet=True)
    for a, b in zip(jax.tree.leaves(s_full["params"]),
                    jax.tree.leaves(s_resumed["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-5)


def test_disk_double_buffer_survives_torn_commit(tmp_path):
    from repro.ckpt.store import DoubleBufferedCheckpointer

    db = DoubleBufferedCheckpointer(str(tmp_path / "ck"))
    tree = {"w": jnp.arange(8.0), "step": jnp.int32(1)}
    db.save(tree, meta={"step": 1})
    tree2 = {"w": jnp.arange(8.0) * 2, "step": jnp.int32(2)}
    p = db.save(tree2, meta={"step": 2})
    # tear the NEWEST commit's sidecar (crash mid-save of a third commit
    # over the same slot)
    with open(p + ".json", "w") as f:
        f.write("{truncated")
    got, meta = db.restore(tree)
    assert got is not None and meta["step"] == 1  # older commit still valid
    np.testing.assert_allclose(np.asarray(got["w"]), np.arange(8.0))
