"""Token-serving engine (DESIGN.md §9): sequence lifecycle over the
preemptive scheduler, bit-identity of decode rounds under forced
preemption at every chunk boundary (same-region, cross-region, and
cross-shell migration), oracle identity of the streamed tokens, the
``repro.Client`` facade, and the deprecated ``Controller`` shim."""
import threading
import time

import numpy as np
import pytest

import repro
from repro.controller.kernels import get_kernel
from repro.core.interrupts import EventKind
from repro.core.scheduler import Scheduler, SchedulerConfig
from repro.core.shell import Shell
from repro.core.task import Task, TaskStatus
from repro.serving.engine import ServingConfig, ServingEngine
from repro.serving.kernels import COL_ACTIVE, COL_LAST_TOK, COL_N_EMIT
from repro.serving.kernels import oracle_stream
from repro.serving.sequence import (SamplingParams, SequenceCancelled,
                                    SequenceStatus)

D_MODEL = 32
VOCAB = 257


# ------------------------------------------------------------ direct drive
def _decode_task(rng, S=3, D=D_MODEL, R=4, vocab=VOCAB):
    """A standalone SeqDecode round over arbitrary slot state — preemption
    bit-identity does not depend on how the state was produced."""
    kd = get_kernel("SeqDecode")
    state = rng.integers(-2**31, 2**31, size=(S, D), dtype=np.int64)
    state = state.astype(np.int32)
    slots = np.zeros((S, 8), np.int32)
    slots[:, COL_ACTIVE] = 1
    slots[:, COL_N_EMIT] = R
    slots[:, COL_LAST_TOK] = rng.integers(0, vocab, size=S)
    slots[S - 1, COL_ACTIVE] = 0  # one dead slot: masking must hold
    out = np.zeros((S, R), np.int32)
    return Task(kernel="SeqDecode",
                args=kd.bundle(out, state, slots, S=S, D=D, R=R,
                               vocab=vocab),
                priority=2)


def _drive(shell, task, preempt_at=None, resume_region=None, timeout=60.0):
    """Like tests/test_chunk_pipeline._drive, but the boundary count is
    relative to the shell's current chunk total so one shell can be
    reused across the whole preemption matrix."""
    regions = shell.regions
    target = regions[0]
    base = sum(r.stats.chunks for r in regions)
    target.enqueue_reconfig(task)
    target.enqueue_launch(task)
    armed = preempt_at is not None
    preemptions = 0
    total = lambda: sum(r.stats.chunks for r in regions) - base
    deadline = time.perf_counter() + timeout
    while True:
        assert time.perf_counter() < deadline, f"stuck: {task}"
        ev = shell.interrupts.wait(0.0005)
        if ev is not None and ev.kind is EventKind.TASK_DONE:
            break
        if ev is not None and ev.kind is EventKind.TASK_PREEMPTED:
            preemptions += 1
            target.cancel_preempt()
            target = resume_region if resume_region is not None else target
            target.enqueue_reconfig(task)
            target.enqueue_launch(task)
            continue
        if armed and total() >= preempt_at:
            armed = False
            target.request_preempt()
    for r in regions:
        r.cancel_preempt()
    return preemptions


def _round_out(task):
    return tuple(np.asarray(b) for b in task.result[:3])


def test_decode_round_preempt_every_boundary_bit_identical():
    """A decode round checkpoint-preempted at EVERY chunk boundary —
    resumed on the same region and on the other region — streams the
    same tokens and leaves the same slot state as the uninterrupted
    round, bit for bit."""
    R = 4
    shell = Shell(n_regions=2, chunk_budget=1, prefetch=False)
    for r in shell.regions:
        r.slowdown_s = 0.02
    try:
        ref_task = _decode_task(np.random.default_rng(0), R=R)
        _drive(shell, ref_task)
        ref = _round_out(ref_task)
        assert np.any(ref[0][:2] != 0)  # live rows actually emitted
        assert np.all(ref[0][2] == 0)   # the dead slot stayed masked
        for resume in (None, shell.regions[1]):
            for k in range(R):
                t = _decode_task(np.random.default_rng(0), R=R)
                _drive(shell, t, preempt_at=k, resume_region=resume)
                got = _round_out(t)
                where = "same" if resume is None else "cross"
                assert all(np.array_equal(a, b)
                           for a, b in zip(got, ref)), \
                    f"{where}-region resume diverged at boundary {k}"
    finally:
        shell.shutdown()


def test_cross_shell_migration_mid_decode_bit_identical():
    """Checkpoint-migrating a RUNNING decode round to another shell
    (host-materialised spill, different region set) must not perturb the
    token stream."""
    from repro.cluster import ClusterFrontend

    ref_shell = Shell(n_regions=1, chunk_budget=1, prefetch=False)
    try:
        ref_task = _decode_task(np.random.default_rng(1), R=6)
        _drive(ref_shell, ref_task)
        ref = _round_out(ref_task)
    finally:
        ref_shell.shutdown()

    fe = ClusterFrontend(n_shells=2, regions_per_shell=1, chunk_budget=1,
                         rebalance=False)
    for node in fe.nodes:
        for r in node.shell.regions:
            r.slowdown_s = 0.02
    try:
        t = _decode_task(np.random.default_rng(1), R=6)
        h = fe.submit(t)
        deadline = time.perf_counter() + 20.0
        migrated = False
        while time.perf_counter() < deadline and not migrated:
            if t.status is TaskStatus.RUNNING and fe.migrate(tid=t.tid):
                migrated = True
                break
            time.sleep(0.002)
        assert migrated, "forced migration never completed"
        out = h.result(timeout=60.0)
        assert h.n_migrations == 1
        got = tuple(np.asarray(b) for b in out[:3])
        assert all(np.array_equal(a, b) for a, b in zip(got, ref))
    finally:
        rep = fe.shutdown()
    assert rep["stranded_handles"] == 0 and rep["lost_tasks"] == 0


# ---------------------------------------------------------- engine lifecycle
@pytest.fixture
def served_shell():
    shell = Shell(n_regions=2, chunk_budget=2, prefetch=False)
    sched = Scheduler(shell, SchedulerConfig())
    th = threading.Thread(target=sched.run_forever, daemon=True)
    th.start()
    sched.wait_until_serving(timeout=10.0)
    yield shell, sched
    sched.drain(timeout=30.0)
    shell.shutdown()


def _cfg(**kw):
    kw.setdefault("d_model", D_MODEL)
    kw.setdefault("vocab_size", VOCAB)
    return ServingConfig(**kw)


def test_sequence_lifecycle_matches_oracle(served_shell):
    """prefill -> slot insert -> N decode rounds -> eviction, with the
    streamed tokens bit-identical to the NumPy oracle for every sequence,
    regardless of batch composition."""
    shell, sched = served_shell
    engine = ServingEngine(sched, _cfg(max_slots=2, round_tokens=3)).start()
    rng = np.random.default_rng(2)
    specs = []
    handles = []
    for i in range(4):  # 4 seqs through 2 slots: forced admission waves
        prompt = [int(x) for x in rng.integers(0, VOCAB, size=2 + i)]
        mx = 2 + 2 * i
        specs.append((prompt, i, mx))
        handles.append(engine.submit(
            prompt, SamplingParams(max_new_tokens=mx, seed=i)))
    for h, (prompt, sd, mx) in zip(handles, specs):
        got = h.result(timeout=120.0)
        assert got == oracle_stream(prompt, sd, mx, D_MODEL, VOCAB)
        assert h.status is SequenceStatus.FINISHED
        assert h.sequence.time_to_first_token is not None
    rep = engine.drain(timeout=30.0)
    assert rep["n_finished"] == 4 and rep["n_failed"] == 0
    assert rep["stranded_sequences"] == 0
    assert rep["prefill_tasks"] == 4
    assert rep["slot_inserts"] == 4 and rep["slot_evictions"] == 4
    assert rep["max_slots_used"] == 2
    assert rep["tokens_out"] == sum(mx for _, _, mx in specs)
    assert rep["decode_rounds"] >= 2  # waves: the batch recomposed


def test_streaming_iterator_yields_incrementally(served_shell):
    shell, sched = served_shell
    engine = ServingEngine(sched, _cfg(round_tokens=2)).start()
    try:
        prompt = [5, 4, 3]
        h = engine.submit(prompt, SamplingParams(max_new_tokens=6, seed=9))
        got = list(h)  # blocking iterator, token by token
        assert got == oracle_stream(prompt, 9, 6, D_MODEL, VOCAB)
    finally:
        engine.shutdown(timeout=30.0)


def test_cancel_waiting_sequence(served_shell):
    shell, sched = served_shell
    engine = ServingEngine(sched, _cfg())  # not started: stays WAITING
    h = engine.submit([1, 2, 3], SamplingParams(max_new_tokens=4))
    assert engine.cancel(h.sid)
    assert h.status is SequenceStatus.CANCELLED
    with pytest.raises(SequenceCancelled):
        h.result(timeout=1.0)
    rep = engine.shutdown(timeout=5.0)
    assert rep["n_cancelled"] == 1 and rep["stranded_sequences"] == 0


def test_engine_forced_preemption_streams_bit_identical():
    """The engine's preempt probe checkpoint-preempts live decode rounds;
    every stream must still match the oracle exactly."""
    shell = Shell(n_regions=2, chunk_budget=1, prefetch=False)
    for r in shell.regions:
        r.slowdown_s = 0.02
    sched = Scheduler(shell, SchedulerConfig())
    th = threading.Thread(target=sched.run_forever, daemon=True)
    th.start()
    sched.wait_until_serving(timeout=10.0)
    engine = ServingEngine(sched, _cfg(
        round_tokens=4, preempt_probe_every=1,
        decode_regions=(shell.regions[1].rid,))).start()
    try:
        rng = np.random.default_rng(3)
        handles, specs = [], []
        for i in range(3):
            prompt = [int(x) for x in rng.integers(0, VOCAB, size=3)]
            specs.append((prompt, i))
            handles.append(engine.submit(
                prompt, SamplingParams(max_new_tokens=8, seed=i)))
        for h, (prompt, sd) in zip(handles, specs):
            assert h.result(timeout=120.0) == oracle_stream(
                prompt, sd, 8, D_MODEL, VOCAB)
        rep = engine.drain(timeout=30.0)
        assert rep["decode_preemptions"] >= 1
        assert rep["stranded_sequences"] == 0
    finally:
        sched.drain(timeout=30.0)
        shell.shutdown()


# ------------------------------------------------------------ client facade
def test_client_submit_and_stream_uniformly():
    """One Client, both verbs: classic task submission and token
    streaming ride the same scheduler loop."""
    from repro.kernels.blur.tasks import make_image

    with repro.Client(n_regions=2, chunk_budget=2,
                      serving=_cfg()) as client:
        rng = np.random.default_rng(4)
        img = make_image(rng, 24)
        h = client.launch("MedianBlur", (img, np.zeros_like(img)),
                          priority=2, H=24, W=24, iters=1)
        out = h.result(timeout=60.0)
        assert np.asarray(out[1]).shape == img.shape
        prompt = [7, 1, 7]
        toks = client.stream(prompt, max_new_tokens=5, seed=2).result(
            timeout=120.0)
        assert toks == oracle_stream(prompt, 2, 5, D_MODEL, VOCAB)
        rep = client.report()
        assert rep["report_version"] == 1
        srep = client.serving_report()
        assert srep["n_finished"] == 1 and srep["stranded_sequences"] == 0


def test_controller_shim_is_deprecated():
    from repro.controller.controller import Controller

    shell = Shell(n_regions=1, chunk_budget=2, prefetch=False)
    try:
        with pytest.warns(DeprecationWarning, match="repro.Client"):
            Controller(shell)
    finally:
        shell.shutdown()
