"""End-to-end behaviour of the paper's system: Controller API, preemptive
scheduling with priorities, partial vs full reconfiguration, service-time
behaviour (paper §6 qualitative claims at test scale)."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.controller.controller import Controller
from repro.controller.hittile import HitTile
from repro.controller.kernels import get_kernel, kernel_names
from repro.core.scheduler import Scheduler, SchedulerConfig
from repro.core.shell import Shell
from repro.core.task import TaskStatus, generate_random_tasks
from repro.kernels.blur.ref import iterated_blur_ref
from repro.kernels.blur.tasks import make_image

SIZE = 30


def test_kernel_registry_has_paper_task_set():
    names = kernel_names()
    assert "MedianBlur" in names and "GaussianBlur" in names
    kd = get_kernel("MedianBlur")
    assert kd.int_args == ("H", "W", "iters")
    # the uniform ABI pads to fixed widths (paper Listing 1.2)
    b = kd.bundle(np.zeros((4, 4), np.float32), np.zeros((4, 4), np.float32),
                  H=2, W=2, iters=1)
    bufs, ints, floats = b.padded()
    from repro.controller.abi import N_BUF_SLOTS
    assert len(bufs) == N_BUF_SLOTS
    assert ints.shape == (8,) and floats.shape == (8,)


def test_controller_end_to_end():
    rng = np.random.default_rng(0)
    img = make_image(rng, SIZE)
    shell = Shell(n_regions=2, chunk_budget=4)
    ctrl = Controller(shell)
    t1 = ctrl.launch("MedianBlur",
                     (HitTile.of(img), HitTile.zeros(img.shape)),
                     priority=1, H=SIZE, W=SIZE, iters=2)
    t2 = ctrl.launch("GaussianBlur",
                     (HitTile.of(img), HitTile.zeros(img.shape)),
                     priority=3, H=SIZE, W=SIZE, iters=1)
    rep = ctrl.run(quiet=True)
    ctrl.shutdown()
    assert rep["n_done"] == 2
    assert t1.status == TaskStatus.DONE and t2.status == TaskStatus.DONE
    ref = np.asarray(iterated_blur_ref(jnp.asarray(img), 2, "median"))
    np.testing.assert_allclose(t1.result[0], ref, atol=1e-5)


def _run_soup(preemption: bool, seed: int = 15, n_tasks: int = 12,
              n_regions: int = 2, rate: float = 0.3,
              slowdown: float = 0.05):
    rng = np.random.default_rng(seed)

    def arg_factory(r, k):
        img = make_image(r, SIZE)
        kd = get_kernel(k)
        return kd.bundle(img, np.zeros_like(img), H=SIZE, W=SIZE,
                         iters=int(r.integers(2, 5)))

    tasks = generate_random_tasks(rng, ["MedianBlur", "GaussianBlur"],
                                  n_tasks, rate, arg_factory)
    # tasks must be long enough (many chunks x slowdown) that urgent
    # arrivals land mid-execution: the chunk-pipelined engine serves a
    # same-bitstream queue head back-to-back on completion (coalescing),
    # so short tasks drain without preemption ever being *needed* — the
    # contention this test is about needs real mid-task arrivals.
    # Prewarm both bitstreams so the cold-compile window (during which a
    # region has no current_task and cannot be chosen as a victim) does
    # not hide the preemption opportunities either.
    shell = Shell(n_regions=n_regions, chunk_budget=1)
    for kname in ("MedianBlur", "GaussianBlur"):
        shell.engine.prewarm(kname, tasks[0].args, (1,))
    for r_ in shell.regions:
        r_.slowdown_s = slowdown
    sched = Scheduler(shell, SchedulerConfig(preemption=preemption))
    rep = sched.run(tasks, quiet=True)
    shell.shutdown()
    return rep, tasks


def test_preemption_reduces_urgent_service_time():
    """Paper Fig. 3 (qualitative): with preemption, high-priority tasks are
    served sooner on average than without."""
    rep_np, tasks_np = _run_soup(False)
    rep_p, tasks_p = _run_soup(True)
    assert rep_np["n_done"] == rep_p["n_done"]
    assert rep_p["preemptions"] > 0, "scenario generated no preemptions"

    def urgent_mean(tasks):
        st = [t.service_time for t in tasks if t.priority <= 1]
        return np.mean(st) if st else 0.0

    # preemptive urgent service-time should not be (much) worse
    assert urgent_mean(tasks_p) <= urgent_mean(tasks_np) * 1.5


def test_reconfiguration_cache_hits():
    """Repeated kernels on the same region geometry must hit the executable
    cache ('partial bitstream already generated')."""
    rep, _ = _run_soup(True, seed=3, n_tasks=10)
    assert rep["cache_hits"] > 0
    assert rep["cold_compiles"] <= 4  # 2 kernels x <=2 signatures


def test_full_reconfig_mode_slower_than_partial():
    """Paper §6.3: full reconfiguration stalls the fabric; with simulated
    bitstream load times (0.22s vs 0.07s) throughput must drop."""
    rng = np.random.default_rng(15)

    def arg_factory(r, k):
        img = make_image(r, SIZE)
        kd = get_kernel(k)
        return kd.bundle(img, np.zeros_like(img), H=SIZE, W=SIZE, iters=1)

    def run(full_mode):
        tasks = generate_random_tasks(
            np.random.default_rng(15), ["MedianBlur", "GaussianBlur"], 8,
            0.05, arg_factory)
        shell = Shell(n_regions=2, chunk_budget=8,
                      simulate_partial_s=0.0 if full_mode else 0.01,
                      simulate_full_s=0.03 if full_mode else 0.0)
        # prewarm both bitstreams: the comparison is about load policy
        # (partial vs full), not compile noise, which otherwise lands on
        # whichever mode runs first in a cold process
        for kname in ("MedianBlur", "GaussianBlur"):
            shell.engine.prewarm(kname, tasks[0].args,
                                 shell.regions[0].geometry)
        sched = Scheduler(shell, SchedulerConfig(
            preemption=False, full_reconfig_mode=full_mode))
        rep = sched.run(tasks, quiet=True)
        shell.shutdown()
        return rep

    rep_partial = run(False)
    rep_full = run(True)
    assert rep_full["full_reconfigs"] > 0
    assert rep_partial["throughput_tps"] > rep_full["throughput_tps"]
