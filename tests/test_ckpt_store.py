"""Checkpoint store integrity: sidecar leaf-count validation, per-leaf
CRC32 checksums, corrupt-file rejection, and the double-buffered fallback.
Cross-shell migration (repro/cluster) trusts these files verbatim, so a
corrupt checkpoint must fail the load loudly rather than resume wrong."""
import json
import os

import numpy as np
import pytest

try:  # property tests degrade to deterministic variants without the dep
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on minimal containers
    HAVE_HYPOTHESIS = False

from repro.ckpt.store import (CheckpointCorruptError,
                              DoubleBufferedCheckpointer, load_pytree,
                              save_pytree)


def _tree(rng, n_leaves=3):
    return {"a": [rng.standard_normal((4, 5)).astype(np.float32)
                  for _ in range(n_leaves)],
            "b": rng.integers(0, 100, size=(7,), dtype=np.int32)}


def _assert_trees_equal(got, want):
    import jax

    for g, w in zip(jax.tree.flatten(got)[0], jax.tree.flatten(want)[0]):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_roundtrip_bit_identical(tmp_path, rng):
    tree = _tree(rng)
    path = str(tmp_path / "ckpt.npz")
    save_pytree(path, tree, meta={"step": 3})
    loaded = load_pytree(path, tree)
    _assert_trees_equal(loaded, tree)
    with open(path + ".json") as f:
        sc = json.load(f)
    assert sc["n_leaves"] == 4 and len(sc["checksums"]) == 4
    assert sc["meta"] == {"step": 3}


def test_corrupt_array_file_raises(tmp_path, rng):
    tree = _tree(rng)
    path = str(tmp_path / "ckpt.npz")
    save_pytree(path, tree)
    blob = bytearray(open(path, "rb").read())
    blob[len(blob) // 2] ^= 0xFF  # flip a payload byte
    with open(path, "wb") as f:
        f.write(blob)
    with pytest.raises(CheckpointCorruptError):
        load_pytree(path, tree)


def test_truncated_file_raises(tmp_path, rng):
    tree = _tree(rng)
    path = str(tmp_path / "ckpt.npz")
    save_pytree(path, tree)
    blob = open(path, "rb").read()
    with open(path, "wb") as f:
        f.write(blob[: len(blob) // 3])
    with pytest.raises(CheckpointCorruptError):
        load_pytree(path, tree)


def test_sidecar_leaf_count_mismatch_raises(tmp_path, rng):
    tree = _tree(rng)
    path = str(tmp_path / "ckpt.npz")
    save_pytree(path, tree)
    with open(path + ".json") as f:
        sc = json.load(f)
    sc["n_leaves"] = 99
    with open(path + ".json", "w") as f:
        json.dump(sc, f)
    with pytest.raises(CheckpointCorruptError, match="sidecar recorded 99"):
        load_pytree(path, tree)


def test_checksum_mismatch_raises_and_unverified_load_passes(tmp_path, rng):
    tree = _tree(rng)
    path = str(tmp_path / "ckpt.npz")
    save_pytree(path, tree)
    with open(path + ".json") as f:
        sc = json.load(f)
    sc["checksums"][1] = "deadbeef"
    with open(path + ".json", "w") as f:
        json.dump(sc, f)
    with pytest.raises(CheckpointCorruptError, match="leaf_1 checksum"):
        load_pytree(path, tree)
    # verify=False and sidecar-less (legacy) loads still work structurally
    loaded = load_pytree(path, tree, verify=False)
    _assert_trees_equal(loaded, tree)
    os.remove(path + ".json")
    _assert_trees_equal(load_pytree(path, tree), tree)


def test_like_structure_mismatch_still_valueerror(tmp_path, rng):
    tree = _tree(rng)
    path = str(tmp_path / "ckpt.npz")
    save_pytree(path, tree)
    with pytest.raises(ValueError, match="expected 2"):
        load_pytree(path, {"a": [tree["a"][0]], "b": tree["b"]})


def test_double_buffer_falls_back_to_older_valid_commit(tmp_path, rng):
    db = DoubleBufferedCheckpointer(str(tmp_path / "db"))
    t1 = _tree(rng)
    t2 = _tree(rng)
    p1 = db.save(t1, meta={"step": 1})
    p2 = db.save(t2, meta={"step": 2})
    assert p1 != p2
    got, meta = db.restore(t1)
    _assert_trees_equal(got, t2)
    assert meta == {"step": 2}
    # corrupt the newest buffer: restore must fall back to the older one
    blob = bytearray(open(p2, "rb").read())
    blob[len(blob) // 2] ^= 0xFF
    with open(p2, "wb") as f:
        f.write(blob)
    got, meta = db.restore(t1)
    _assert_trees_equal(got, t1)
    assert meta == {"step": 1}
    # both corrupt -> no valid commit, not an exception
    blob = bytearray(open(p1, "rb").read())
    blob[len(blob) // 2] ^= 0xFF
    with open(p1, "wb") as f:
        f.write(blob)
    assert db.restore(t1) == (None, None)


if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None,
              suppress_health_check=[HealthCheck.function_scoped_fixture])
    @given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 6))
    def test_roundtrip_property(tmp_path, seed, n):
        rng = np.random.default_rng(seed)
        tree = {"x": [rng.standard_normal((n, 3)).astype(np.float32)
                      for _ in range(n)],
                "i": rng.integers(-5, 5, size=(n,), dtype=np.int32)}
        path = str(tmp_path / f"p{seed}.npz")
        save_pytree(path, tree)
        _assert_trees_equal(load_pytree(path, tree), tree)

else:  # deterministic fallback

    def test_roundtrip_property(tmp_path, rng):
        for n in (1, 4):
            tree = {"x": [rng.standard_normal((n, 3)).astype(np.float32)
                          for _ in range(n)],
                    "i": rng.integers(-5, 5, size=(n,), dtype=np.int32)}
            path = str(tmp_path / f"p{n}.npz")
            save_pytree(path, tree)
            _assert_trees_equal(load_pytree(path, tree), tree)
