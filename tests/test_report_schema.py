"""Versioned report schema (``core/reporting.py``): every key actually
emitted by the four report layers — scheduler, shell_reconfig, cluster,
serving — must be documented in ``SCHEMA``, and every report carries the
``report_version`` / ``layer`` envelope."""
import numpy as np

from repro.core.reporting import (REPORT_VERSION, SCHEMA, documented_keys,
                                  undocumented)


def _check(layer, rep):
    assert rep["report_version"] == REPORT_VERSION
    assert rep["layer"] == layer
    extra = undocumented(layer, rep)
    assert not extra, (f"{layer} report emits undocumented keys {extra}; "
                       f"document them in core/reporting.py SCHEMA")


def test_schema_layers_complete():
    assert set(SCHEMA) == {"scheduler", "shell_reconfig", "cluster",
                           "serving"}
    for layer in SCHEMA:
        assert documented_keys(layer), layer


def test_scheduler_and_shell_reports_documented():
    from repro.controller.kernels import get_kernel
    from repro.core.scheduler import Scheduler, SchedulerConfig
    from repro.core.shell import Shell
    from repro.core.task import Task
    from repro.kernels.blur.tasks import make_image

    rng = np.random.default_rng(0)
    img = make_image(rng, 16)
    kd = get_kernel("MedianBlur")
    shell = Shell(n_regions=1, chunk_budget=2, prefetch=False)
    try:
        t = Task(kernel="MedianBlur",
                 args=kd.bundle(img, np.zeros_like(img), H=16, W=16,
                                iters=1),
                 priority=2)
        rep = Scheduler(shell, SchedulerConfig()).run([t], quiet=True)
        _check("scheduler", rep)
        _check("shell_reconfig", shell.reconfig_report())
    finally:
        shell.shutdown()


def test_cluster_report_documented():
    from repro.cluster import ClusterFrontend

    fe = ClusterFrontend(n_shells=2, regions_per_shell=1, chunk_budget=2,
                         rebalance=False)
    rep = fe.shutdown()
    _check("cluster", rep)


def test_serving_report_documented():
    from repro.serving.engine import ServingConfig, ServingEngine

    class _NullBackend:
        def submit(self, task):  # pragma: no cover - never dispatched
            raise AssertionError("schema test never dispatches")

    engine = ServingEngine(_NullBackend(), ServingConfig())
    rep = engine.report()
    _check("serving", rep)


def test_trace_section_schema():
    """The ``trace`` key: ``{enabled: False}`` untraced; under a tracer it
    carries the recorder counters plus every derived-metrics section the
    schema doc promises (per-task breakdown, preempt response, regions,
    ICAP) — still as ONE documented top-level key."""
    from repro.controller.kernels import get_kernel
    from repro.core.scheduler import Scheduler, SchedulerConfig
    from repro.core.shell import Shell
    from repro.core.task import Task
    from repro.kernels.blur.tasks import make_image
    from repro.obs import Tracer

    rng = np.random.default_rng(1)
    img = make_image(rng, 16)
    kd = get_kernel("MedianBlur")
    shell = Shell(n_regions=1, chunk_budget=2, prefetch=False,
                  tracer=Tracer())
    try:
        t = Task(kernel="MedianBlur",
                 args=kd.bundle(img, np.zeros_like(img), H=16, W=16,
                                iters=1))
        rep = Scheduler(shell, SchedulerConfig()).run([t], quiet=True)
    finally:
        shell.shutdown()
    _check("scheduler", rep)
    tr = rep["trace"]
    assert tr["enabled"] is True
    for key in ("capacity", "emitted", "dropped", "n_events", "kinds",
                "per_task", "preempt_response", "regions", "icap"):
        assert key in tr, key
    assert tr["per_task"]["n_tasks"] == 1

    # untraced runs keep the key but flag it disabled
    shell2 = Shell(n_regions=1, chunk_budget=2, prefetch=False)
    try:
        rep2 = Scheduler(shell2, SchedulerConfig()).report()
    finally:
        shell2.shutdown()
    assert rep2["trace"] == {"enabled": False}


def test_telemetry_section_schema():
    """The ``telemetry`` key: ``{enabled: False}`` unmetered; with a
    registry + monitor threaded it carries series counts plus the full
    alert/detector/SLO state — still as ONE documented top-level key."""
    from repro.controller.kernels import get_kernel
    from repro.core.scheduler import Scheduler, SchedulerConfig
    from repro.core.shell import Shell
    from repro.core.task import Task
    from repro.kernels.blur.tasks import make_image
    from repro.obs import MetricsRegistry, TelemetryMonitor

    rng = np.random.default_rng(2)
    img = make_image(rng, 16)
    kd = get_kernel("MedianBlur")
    reg = MetricsRegistry()
    shell = Shell(n_regions=1, chunk_budget=2, prefetch=False, metrics=reg)
    try:
        sched = Scheduler(shell, SchedulerConfig())
        mon = TelemetryMonitor(reg).attach(scheduler=sched)
        t = Task(kernel="MedianBlur",
                 args=kd.bundle(img, np.zeros_like(img), H=16, W=16,
                                iters=1))
        sched.run([t], quiet=True)
        mon.sample()
        rep = sched.report()
    finally:
        shell.shutdown()
    _check("scheduler", rep)
    tele = rep["telemetry"]
    assert tele["enabled"] is True and tele["sampler"] is True
    for key in ("n_series", "alerts", "alerts_fired_total", "detectors",
                "slo", "samples"):
        assert key in tele, key
    assert tele["samples"] >= 1 and tele["n_series"] > 0
    assert tele["alerts"] == []

    # unmetered runs keep the key but flag it disabled
    shell2 = Shell(n_regions=1, chunk_budget=2, prefetch=False)
    try:
        rep2 = Scheduler(shell2, SchedulerConfig()).report()
    finally:
        shell2.shutdown()
    assert rep2["telemetry"] == {"enabled": False}
